"""Golden equivalence for the batched online engine (core/sim_online_batch).

The contract under test is the belief-vs-truth split of ``Session.run_online``:
planning sees only the EWMA estimator's belief (seeded from the trace at t=0,
fed back from the uploads the plans actually perform), while execution audits
offload completions against the *true* trace.  The batched engine carries the
estimator state through a jitted while-loop, vmapped over whole grids; these
goldens pin it to the reference loop — integer stats and round counts exactly,
accuracy sums within AUDIT_TOL, and the final believed bandwidth bit-for-bit
(the EWMA chain is guarded against XLA fma/reassociation rewrites).

Also here: the regression tests for the estimator-belief bugfix this engine
was certified against — ``observe_rtt`` must *seed* from the first real RTT
sample instead of blending it into the 0.1 s stub prior.
"""
from __future__ import annotations

import logging

import pytest

from repro.core import PolicySpec
from repro.core.audit import AUDIT_TOL
from repro.core.controller import BandwidthEstimator
from repro.core.registry import available_policies, get_policy
from repro.core.sim_online_batch import (
    OnlineScenario,
    batched_online_policies,
    simulate_online_batch,
)
from repro.scenariogen import edge_failure
from repro.session import FleetSpec, ScenarioSpec, Session, SweepGrid, TraceSpec

# Every batched_online policy with (base params, the param axis swept in the
# golden lattice).  test_registry_flag below fails if a policy registers
# batched_online=True without joining this table.
ONLINE_PARAMS: dict[str, tuple[dict, dict]] = {
    "max_accuracy": ({"grid": 0.01}, {"grid": (0.01, 0.02)}),
    "max_utility": ({"alpha": 200.0}, {"alpha": (50.0, 200.0)}),
}

INT_FIELDS = (
    "frames_processed",
    "frames_missed_deadline",
    "frames_offloaded",
    "frames_total",
    "schedule_calls",
)

# Walking in/out of coverage: 3.5 Mbps for the first second, 0.8 after — the
# estimator starts believing 3.5 and has to learn the collapse from its own
# uploads.
SQUARE = TraceSpec(
    kind="piecewise", points=((0.0, 3.5), (1.0, 0.8)), rtt_ms=100.0
)


def _spec(name: str, params: dict, trace: TraceSpec, n_frames: int) -> ScenarioSpec:
    return ScenarioSpec(
        policy=PolicySpec(name, params), n_frames=n_frames, trace=trace
    )


def _assert_online_equal(ref, bat):
    """ints + rounds exact, accuracy within AUDIT_TOL, belief bit-exact."""
    assert len(ref.points) == len(bat.points)
    for pr, pb in zip(ref.points, bat.points):
        assert pr.overrides == pb.overrides
        (sr,), (sb,) = pr.streams, pb.streams
        for f in INT_FIELDS:
            assert getattr(sr, f) == getattr(sb, f), (pr.overrides, f)
        assert abs(sr.accuracy_sum - sb.accuracy_sum) <= AUDIT_TOL, pr.overrides
        assert pr.meta["rounds"] == pb.meta["rounds"], pr.overrides
        assert pr.meta["estimated_bps"] == pb.meta["estimated_bps"], pr.overrides


def test_registry_flag_matches_online_backend_table():
    flagged = {n for n in available_policies() if get_policy(n).batched_online}
    assert set(batched_online_policies()) == flagged
    assert set(ONLINE_PARAMS) == flagged


@pytest.mark.parametrize("name", sorted(ONLINE_PARAMS))
def test_online_equivalence_square_wave(name):
    """Fast golden: one shape bucket, square-wave trace, both rtts."""
    base, _ = ONLINE_PARAMS[name]
    spec = _spec(name, base, SQUARE, n_frames=45)
    grid = SweepGrid(rtt_ms=(60.0, 100.0))
    ref = Session(spec).run_sweep(grid, backend="reference", mode="online")
    bat = Session(spec).run_sweep(grid, backend="batched", mode="online")
    assert ref.backend == "reference" and bat.backend == "batched"
    assert bat.meta["engine"] == "sim_online_batch"
    assert ref.meta["mode"] == bat.meta["mode"] == "online"
    _assert_online_equal(ref, bat)


@pytest.mark.parametrize("name", sorted(ONLINE_PARAMS))
def test_online_equivalence_fault_injection(name):
    """Golden with an injected mid-run edge failure: the monitor-detected
    outage window collapses the trace to 0.05 Mbps; the controller has to
    discover both the outage and the recovery from its own uploads."""
    base, _ = ONLINE_PARAMS[name]
    outage = edge_failure(
        fail_at_s=2.0, recover_at_s=5.0, duration_s=8.0, base_mbps=3.5
    )
    assert outage.detected_at_s > outage.fail_at_s  # detection lags the crash
    spec = _spec(name, base, outage.trace, n_frames=180)  # 6 s: spans the outage
    grid = SweepGrid(rtt_ms=(60.0, 100.0))
    ref = Session(spec).run_sweep(grid, backend="reference", mode="online")
    bat = Session(spec).run_sweep(grid, backend="batched", mode="online")
    _assert_online_equal(ref, bat)


def test_online_equivalence_dead_link_from_start():
    """A link dead from t=0 seeds the belief at 0 bps: planning must go
    all-local on both engines (no offloads, no misses) and stay equivalent."""
    dead = TraceSpec(kind="constant", mbps=0.0, rtt_ms=100.0)
    spec = _spec("max_accuracy", {"grid": 0.01}, dead, n_frames=45)
    grid = SweepGrid()
    ref = Session(spec).run_sweep(grid, backend="reference", mode="online")
    bat = Session(spec).run_sweep(grid, backend="batched", mode="online")
    _assert_online_equal(ref, bat)
    assert bat.points[0].stats.frames_offloaded == 0
    assert bat.points[0].stats.frames_missed_deadline == 0


@pytest.mark.slow
@pytest.mark.parametrize("name", sorted(ONLINE_PARAMS))
def test_online_golden_lattice(name):
    """The certification lattice: deadlines x rtts x a param axis over the
    square-wave trace — multiple shape buckets, 12 points per policy."""
    base, axis = ONLINE_PARAMS[name]
    spec = _spec(name, base, SQUARE, n_frames=90)
    grid = SweepGrid(deadline_ms=(150.0, 200.0, 250.0), rtt_ms=(60.0, 100.0), params=axis)
    assert len(grid) == 12
    ref = Session(spec).run_sweep(grid, backend="reference", mode="online")
    bat = Session(spec).run_sweep(grid, backend="batched", mode="online")
    _assert_online_equal(ref, bat)


def test_online_estimator_converges_on_square_wave():
    """Belief-vs-truth: after the 1 s collapse from 3.5 to 0.8 Mbps, the
    EWMA belief must leave the initial 3.5e6 seed and settle inside the
    trace's band (pessimism keeps the reported state below the raw EWMA)."""
    spec = _spec("max_accuracy", {"grid": 0.01}, SQUARE, n_frames=240)
    rep = Session(spec).run_online()
    est = rep.meta["estimated_bps"]
    assert est < 3.5e6 * 0.9  # moved off the optimistic seed
    assert est > 0.8e6 * 0.5  # did not collapse below the true floor band
    assert rep.meta["rounds"] == rep.streams[0].schedule_calls


def test_optimistic_initial_estimate_surfaces_as_audited_misses():
    """The estimator seeds from the trace at t=0; when the link collapses one
    frame later, the stale optimistic belief keeps planning offloads the true
    link cannot complete — the audit must charge those as deadline misses.
    An honest belief (constant low trace) plans local and misses nothing."""
    collapse = TraceSpec(
        kind="piecewise", points=((0.0, 3.5), (0.01, 0.05)), rtt_ms=100.0
    )
    honest = TraceSpec(kind="constant", mbps=0.05, rtt_ms=100.0)
    opt = Session(_spec("max_accuracy", {"grid": 0.01}, collapse, 60)).run_online()
    hon = Session(_spec("max_accuracy", {"grid": 0.01}, honest, 60)).run_online()
    assert opt.streams[0].frames_missed_deadline > 0
    assert hon.streams[0].frames_missed_deadline == 0
    assert opt.meta["estimated_bps"] < 3.5e6 * 0.9  # the misses taught it


def test_online_engine_init_bps_override_models_stale_belief():
    """OnlineScenario.init_bps decouples the seed from the trace: an
    optimistic stale belief over a slow link must cost misses that an honest
    seed avoids."""
    scen = dict(
        stream=ScenarioSpec(policy=PolicySpec("max_accuracy", {"grid": 0.01})).stream,
        n_frames=60,
        params={"grid": 0.01},
        rtt=0.1,
        bw_segments=((0.0, 0.05e6),),
    )
    models = list(ScenarioSpec(policy=PolicySpec("max_accuracy")).models)
    (st_opt, _), (st_hon, _) = simulate_online_batch(
        "max_accuracy",
        models,
        [
            OnlineScenario(**scen, init_bps=3.5e6),
            OnlineScenario(**scen),  # seeds from the trace: honest
        ],
    )
    assert st_opt.frames_missed_deadline > st_hon.frames_missed_deadline
    assert st_hon.frames_missed_deadline == 0


def test_online_sweep_falls_back_without_batched_online_backend(caplog):
    """jax_accuracy is batched for oracle sweeps but has no online backend:
    forcing backend='batched' warns, records the fallback, and still returns
    reference-loop results."""
    spec = ScenarioSpec(policy=PolicySpec("jax_accuracy"), n_frames=30, trace=SQUARE)
    grid = SweepGrid(rtt_ms=(60.0, 100.0))
    with caplog.at_level(logging.WARNING, logger="repro.session"):
        rep = Session(spec).run_sweep(grid, backend="batched", mode="online")
    assert rep.backend == "reference"
    assert "no batched online backend" in rep.meta["fallback"]
    assert any("falling back" in r.message for r in caplog.records)
    ref = Session(spec).run_sweep(grid, backend="reference", mode="online")
    _assert_online_equal(ref, rep)
    # auto routing makes the same decision silently
    auto = Session(spec).run_sweep(grid, mode="online")
    assert auto.backend == "reference"
    assert "fallback" not in auto.meta


def test_online_sweep_rejects_fleet_grids():
    spec = ScenarioSpec(
        policy=PolicySpec("max_accuracy"), n_frames=30, fleet=FleetSpec(n_clients=2)
    )
    with pytest.raises(ValueError, match="single-stream"):
        Session(spec).run_sweep(SweepGrid(), mode="online")


def test_online_sweep_rejects_track_workload():
    spec = ScenarioSpec(
        policy=PolicySpec("track_fixed", {"k": 3}),
        n_frames=30,
        workload="track",
    )
    with pytest.raises(ValueError, match="tracking workload"):
        Session(spec).run_sweep(SweepGrid(), mode="online")


def test_simulate_online_batch_rejects_unregistered_policy():
    models = list(ScenarioSpec(policy=PolicySpec("max_accuracy")).models)
    with pytest.raises(ValueError, match="batched online"):
        simulate_online_batch("jax_accuracy", models, [])
    assert simulate_online_batch("max_accuracy", models, []) == []


# ---------------------------------------------------------------------------
# Estimator-belief regressions (the bugfix this engine was certified against)
# ---------------------------------------------------------------------------


def test_first_rtt_sample_seeds_the_belief():
    """The 0.1 s default is a stub prior, not a measurement: the first real
    RTT observation must *replace* it, not blend into it."""
    est = BandwidthEstimator()
    assert est.state().rtt == 0.1  # stub prior before any observation
    assert est.rtt_samples == 0
    est.observe_rtt(0.27)
    assert est.state().rtt == 0.27  # seeded exactly, no trace of the prior
    assert est.rtt_samples == 1


def test_later_rtt_samples_blend_by_ewma():
    est = BandwidthEstimator(beta=0.3)
    est.observe_rtt(0.2)
    est.observe_rtt(0.1)
    assert est.state().rtt == pytest.approx(0.7 * 0.2 + 0.3 * 0.1)
    assert est.rtt_samples == 2
