"""Golden equivalence for the vectorized multi-stream fleet backend.

The contract under test (docs/simulation.md, "Multi-stream fleet grids"):
for every policy with a fleet planner in ``core/sim_multi_batch``,
``Session.run_sweep`` on a fleet grid reproduces the reference
``simulate_multi`` event loop's audited stats — integer stats (frames
processed / offloaded / missed, server jobs, scheduler grants/denials)
**exactly**, float stats within the certified ``MULTI_TOL``.  Plus:
registry-flag <-> planner-table sync, the logged fallback for Python-only
policies and non-constant traces, and the structured ``PlanError`` audit
path of ``simulate_multi`` itself.
"""
from __future__ import annotations

import logging

import pytest

from repro.core import (
    EdgeServerScheduler,
    PolicySpec,
    Trace,
    make_fleet,
    simulate_multi,
)
from repro.core.registry import available_policies, get_policy
from repro.core.schedule import Decision, RoundPlan, Where, validate_plan
from repro.core.sim_multi_batch import (
    EQUIV_INT_FIELDS,
    MULTI_TOL,
    FleetScenario,
    multi_batched_policies,
    simulate_multi_batch,
)
from repro.session import FleetSpec, ScenarioSpec, Session, SweepGrid, TraceSpec

GOLD_FRAMES = 16


def _fleet_session(policy="offload", params=None, **fleet_kw):
    fleet_kw.setdefault("capacity", 2)
    return Session(
        ScenarioSpec(
            policy=PolicySpec(policy, params or {}),
            n_frames=GOLD_FRAMES,
            trace=TraceSpec(mbps=6.0),
            fleet=FleetSpec(**fleet_kw),
        )
    )


def _assert_fleet_reports_equal(ref, bat):
    assert len(ref.points) == len(bat.points)
    for pr, pb in zip(ref.points, bat.points):
        assert pr.overrides == pb.overrides
        assert len(pr.streams) == len(pb.streams), pr.overrides
        for sr, sb in zip(pr.streams, pb.streams):
            for f in EQUIV_INT_FIELDS:
                assert getattr(sr, f) == getattr(sb, f), (pr.overrides, f)
            assert abs(sr.accuracy_sum - sb.accuracy_sum) <= MULTI_TOL, pr.overrides
            assert sr.elapsed == sb.elapsed
        for key in ("allocation", "server_jobs", "grants", "denials"):
            assert pr.meta[key] == pb.meta[key], (pr.overrides, key)
        assert (
            abs(pr.meta["server_utilization"] - pb.meta["server_utilization"])
            <= MULTI_TOL
        ), pr.overrides


# ---------------------------------------------------------------------------
# Registry <-> backend sync
# ---------------------------------------------------------------------------


def test_registry_flag_matches_fleet_planner_table():
    flagged = {n for n in available_policies() if get_policy(n).batched_multi}
    planners = set(multi_batched_policies())
    # Every batched_multi policy has a dedicated fleet planner and vice
    # versa — no replication shortcuts left in the table.
    assert planners == flagged


def test_unknown_policy_raises():
    with pytest.raises(ValueError, match="no batched fleet backend"):
        simulate_multi_batch("local", [], [FleetScenario()])


# ---------------------------------------------------------------------------
# Golden equivalence: batched fleet == simulate_multi
# ---------------------------------------------------------------------------


def test_fleet_grid_matches_reference_small():
    """Fast lane: one allocation pair, one fleet size, contention included
    (6 Mbps across 2 clients forces denials + stretched fifo uploads)."""
    session = _fleet_session()
    grid = SweepGrid(
        bandwidth_mbps=(2.5, 6.0, 12.0),
        n_clients=(2,),
        allocation=("weighted_fair", "fifo"),
    )
    ref = session.run_sweep(grid, backend="reference")
    bat = session.run_sweep(grid, backend="batched")
    assert ref.backend == "reference" and bat.backend == "batched"
    assert bat.meta["engine"] == "sim_multi_batch"
    _assert_fleet_reports_equal(ref, bat)


@pytest.mark.slow
@pytest.mark.parametrize(
    "params", [{}, {"alpha": 150.0}], ids=["accuracy-mode", "utility-mode"]
)
def test_fleet_grid_matches_reference_full(params):
    """The full golden lattice: every allocation policy, mixed fleet sizes,
    deadlines tight enough to force completion-audit misses."""
    session = _fleet_session(params=params)
    grid = SweepGrid(
        bandwidth_mbps=(1.0, 4.0, 9.0),
        deadline_ms=(150.0, 250.0),
        n_clients=(1, 2, 4),
        allocation=("weighted_fair", "priority", "fifo"),
    )
    ref = session.run_sweep(grid, backend="reference")
    bat = session.run_sweep(grid, backend="batched")
    _assert_fleet_reports_equal(ref, bat)


@pytest.mark.slow
def test_fleet_grid_matches_reference_weights_priorities():
    session = Session(
        ScenarioSpec(
            policy=PolicySpec("offload"),
            n_frames=GOLD_FRAMES,
            trace=TraceSpec(mbps=9.0),
            fleet=FleetSpec(
                n_clients=4,
                allocation="priority",
                capacity=1,
                weights=(3.0, 1.0, 1.0, 0.5),
                priorities=(0, 0, 2, 2),
            ),
        )
    )
    grid = SweepGrid(bandwidth_mbps=(4.0, 9.0), deadline_ms=(175.0, 200.0))
    ref = session.run_sweep(grid, backend="reference")
    bat = session.run_sweep(grid, backend="batched")
    _assert_fleet_reports_equal(ref, bat)


def test_direct_backend_call_matches_simulate_multi():
    """One scenario through the raw module API (no Session), asserting the
    MultiStreamStats shape and the scheduler-audit meta."""
    fleet = make_fleet(2, policy=PolicySpec("offload"))
    sched = EdgeServerScheduler(fleet, policy="weighted_fair", capacity=2)
    ms_ref = simulate_multi(sched, Trace.constant(6.0), GOLD_FRAMES)
    (ms_bat, meta), = simulate_multi_batch(
        "offload",
        list(fleet[0].models),
        [
            FleetScenario(
                n_frames=GOLD_FRAMES,
                bandwidth_bps=6.0e6,
                n_clients=2,
                allocation="weighted_fair",
                capacity=2,
            )
        ],
    )
    assert ms_bat.server_jobs == ms_ref.server_jobs
    assert abs(ms_bat.server_busy_s - ms_ref.server_busy_s) <= MULTI_TOL
    assert abs(ms_bat.aggregate_accuracy - ms_ref.aggregate_accuracy) <= MULTI_TOL
    assert ms_bat.miss_rates == ms_ref.miss_rates
    assert meta == {"grants": sched.audit.grants, "denials": sched.audit.denials}


def test_aggregate_accuracy_consistent_with_per_client_stats():
    """MultiStreamStats.aggregate_accuracy must be derivable from the
    audited per-client stats on both backends (the fleet mean over all
    frames, missed = 0) — no hidden accounting."""
    for ms in (
        simulate_multi(
            EdgeServerScheduler(make_fleet(3, policy="offload"), policy="fifo"),
            Trace.constant(4.0),
            GOLD_FRAMES,
        ),
        simulate_multi_batch(
            "offload",
            list(make_fleet(1)[0].models),
            [FleetScenario(n_frames=GOLD_FRAMES, bandwidth_bps=4.0e6,
                           n_clients=3, allocation="fifo")],
        )[0][0],
    ):
        total = sum(s.frames_total for s in ms.per_client)
        acc = sum(s.accuracy_sum for s in ms.per_client)
        assert ms.aggregate_accuracy == pytest.approx(acc / total, abs=0)
        for s in ms.per_client:
            # offload rounds are horizon-1: every frame is processed,
            # missed, or skipped — never double-counted.
            assert s.frames_processed + s.frames_missed_deadline <= s.frames_total
            assert s.frames_offloaded == s.frames_processed


# ---------------------------------------------------------------------------
# Golden fleet lattices for the DP planners (newly batched_multi in this PR):
# per-client planning over granted bandwidth + shared-link contention must
# reproduce the reference event loop — ints exact, accuracy within MULTI_TOL.
# ---------------------------------------------------------------------------

PLANNERS = [
    ("max_accuracy", {}),
    ("max_utility", {"alpha": 150.0}),
    ("jax_accuracy", {}),
    ("jax_utility", {"alpha": 150.0}),
]
PLANNER_IDS = [p for p, _ in PLANNERS]


@pytest.mark.parametrize("policy,params", PLANNERS, ids=PLANNER_IDS)
def test_planner_fleet_grid_matches_reference_small(policy, params):
    """Fast lane: every planner, shared 6 Mbps link across 2 clients,
    weighted_fair (denials at capacity) + fifo (uncapped reservations)."""
    session = _fleet_session(policy=policy, params=params)
    grid = SweepGrid(n_clients=(2,), allocation=("weighted_fair", "fifo"))
    ref = session.run_sweep(grid, backend="reference")
    bat = session.run_sweep(grid, backend="batched")
    assert bat.backend == "batched" and bat.meta["engine"] == "sim_multi_batch"
    _assert_fleet_reports_equal(ref, bat)


@pytest.mark.slow
@pytest.mark.parametrize("policy,params", PLANNERS, ids=PLANNER_IDS)
def test_planner_fleet_golden_lattice_constant(policy, params):
    """The full constant-trace lattice: every allocation policy, mixed fleet
    sizes, bandwidths spanning starved to comfortable."""
    session = _fleet_session(policy=policy, params=params)
    grid = SweepGrid(
        bandwidth_mbps=(1.0, 4.0, 9.0),
        n_clients=(1, 2, 4),
        allocation=("weighted_fair", "priority", "fifo"),
    )
    ref = session.run_sweep(grid, backend="reference")
    bat = session.run_sweep(grid, backend="batched")
    _assert_fleet_reports_equal(ref, bat)


@pytest.mark.slow
@pytest.mark.parametrize("policy,params", PLANNERS, ids=PLANNER_IDS)
def test_planner_fleet_golden_lattice_piecewise(policy, params):
    """Piecewise shared link: uploads granted at 6 Mbps drain into a
    1.5 Mbps trough; the fluid rates re-evaluate at every event boundary."""
    session = Session(
        ScenarioSpec(
            policy=PolicySpec(policy, params),
            n_frames=GOLD_FRAMES,
            trace=TraceSpec(
                kind="piecewise", points=((0.0, 6.0), (0.2, 1.5), (0.35, 9.0))
            ),
            fleet=FleetSpec(n_clients=2, capacity=2),
        )
    )
    grid = SweepGrid(
        n_clients=(1, 3), allocation=("weighted_fair", "priority", "fifo")
    )
    ref = session.run_sweep(grid, backend="reference")
    bat = session.run_sweep(grid, backend="batched")
    assert bat.meta["engine"] == "sim_multi_batch"
    _assert_fleet_reports_equal(ref, bat)


@pytest.mark.slow
@pytest.mark.parametrize("policy,params", PLANNERS, ids=PLANNER_IDS)
def test_planner_fleet_capacity_zero_and_backlog_gated(policy, params):
    """Admission edge cases: capacity 0 denies every lease (plans must fall
    back to local-only rounds) and a tight backlog limit on a starved link
    shuts the allocation gate mid-run."""
    cap0 = _fleet_session(policy=policy, params=params, capacity=0)
    grid0 = SweepGrid(n_clients=(2,), allocation=("weighted_fair", "fifo"))
    _assert_fleet_reports_equal(
        cap0.run_sweep(grid0, backend="reference"),
        cap0.run_sweep(grid0, backend="batched"),
    )
    gated = Session(
        ScenarioSpec(
            policy=PolicySpec(policy, params),
            n_frames=GOLD_FRAMES,
            trace=TraceSpec(mbps=1.0),
            fleet=FleetSpec(n_clients=3, capacity=2, backlog_limit=0.05),
        )
    )
    gridb = SweepGrid(allocation=("weighted_fair",))
    _assert_fleet_reports_equal(
        gated.run_sweep(gridb, backend="reference"),
        gated.run_sweep(gridb, backend="batched"),
    )


@pytest.mark.slow
@pytest.mark.parametrize("policy,params", PLANNERS, ids=PLANNER_IDS)
def test_planner_fleet_weights_priorities(policy, params):
    """Non-uniform weights + priority tiers: effective-weight shares,
    priority reservations and the intra-tick plan order all bite."""
    session = Session(
        ScenarioSpec(
            policy=PolicySpec(policy, params),
            n_frames=GOLD_FRAMES,
            trace=TraceSpec(mbps=9.0),
            fleet=FleetSpec(
                n_clients=4,
                allocation="priority",
                capacity=1,
                weights=(3.0, 1.0, 1.0, 0.5),
                priorities=(0, 0, 2, 2),
            ),
        )
    )
    grid = SweepGrid(bandwidth_mbps=(4.0, 9.0))
    _assert_fleet_reports_equal(
        session.run_sweep(grid, backend="reference"),
        session.run_sweep(grid, backend="batched"),
    )


# ---------------------------------------------------------------------------
# Fallback routing
# ---------------------------------------------------------------------------


def test_offloading_fleet_grid_routes_batched_without_warning(caplog):
    """Regression for the retired PR 5 fallback: fleet grids of the
    offloading planners used to log "no batched fleet backend" and run the
    reference loop.  They now route through the dedicated fleet planner in
    ``sim_multi_batch`` with no fallback warning and no ``fallback`` meta."""
    for policy, params in (("max_accuracy", {}), ("max_utility", {"alpha": 150.0})):
        session = _fleet_session(policy=policy, params=params)
        grid = SweepGrid(bandwidth_mbps=(6.0,), n_clients=(2,))
        with caplog.at_level(logging.WARNING, logger="repro.session"):
            report = session.run_sweep(grid, backend="batched")
        assert report.backend == "batched"
        assert report.meta["engine"] == "sim_multi_batch"
        assert "fallback" not in report.meta
        assert not any("falling back" in r.message for r in caplog.records)
        caplog.clear()


def test_python_only_fleet_grid_warns_and_falls_back(caplog):
    """The genuine fallback still exists: a policy with no vectorized fleet
    backend at all (``local``) logs the documented warning and runs the
    reference loop."""
    session = _fleet_session(policy="local")
    grid = SweepGrid(bandwidth_mbps=(6.0,), n_clients=(2,))
    with caplog.at_level(logging.WARNING, logger="repro.session"):
        report = session.run_sweep(grid, backend="batched")
    assert report.backend == "reference"
    assert "no batched fleet backend" in report.meta["fallback"]
    assert any("falling back" in r.message for r in caplog.records)
    # auto mode falls back silently (it never promised a batched engine).
    caplog.clear()
    with caplog.at_level(logging.WARNING, logger="repro.session"):
        auto = session.run_sweep(grid)
    assert auto.backend == "reference" and not caplog.records


def test_piecewise_trace_fleet_grid_matches_reference():
    """Time-varying shared link: the fleet engine replays the piecewise
    trace on device (allocation at round start, fluid rates at every event
    boundary) and must match the reference event loop — this used to be a
    fallback case."""
    session = Session(
        ScenarioSpec(
            policy=PolicySpec("offload"),
            n_frames=GOLD_FRAMES,
            trace=TraceSpec(
                kind="piecewise", points=((0.0, 6.0), (0.2, 1.5), (0.35, 9.0))
            ),
            fleet=FleetSpec(n_clients=2, capacity=2),
        )
    )
    grid = SweepGrid(
        n_clients=(1, 2, 3), allocation=("weighted_fair", "priority", "fifo")
    )
    ref = session.run_sweep(grid, backend="reference")
    bat = session.run_sweep(grid, backend="batched")
    assert bat.backend == "batched" and bat.meta["engine"] == "sim_multi_batch"
    _assert_fleet_reports_equal(ref, bat)
    # the varying trace really bites: some uploads started at 6 Mbps finish
    # into the 1.5 Mbps trough and miss their deadlines.
    assert any(p.max_miss_rate > 0 for p in bat.points)


def test_direct_fleet_scenario_piecewise_segments():
    """FleetScenario.bw_segments drives the engine directly (no Session):
    equivalence against simulate_multi over the same Trace.piecewise."""
    pts = ((0.0, 5.0), (0.25, 1.0))
    fleet = make_fleet(2, policy=PolicySpec("offload"))
    sched = EdgeServerScheduler(fleet, policy="weighted_fair", capacity=2)
    ms_ref = simulate_multi(sched, Trace.piecewise(list(pts)), GOLD_FRAMES)
    (ms_bat, _), = simulate_multi_batch(
        "offload",
        list(fleet[0].models),
        [
            FleetScenario(
                n_frames=GOLD_FRAMES,
                bw_segments=tuple((t, v * 1e6) for t, v in pts),
                n_clients=2,
                allocation="weighted_fair",
                capacity=2,
            )
        ],
    )
    assert ms_bat.server_jobs == ms_ref.server_jobs
    assert ms_bat.miss_rates == ms_ref.miss_rates
    assert abs(ms_bat.aggregate_accuracy - ms_ref.aggregate_accuracy) <= MULTI_TOL


# ---------------------------------------------------------------------------
# simulate_multi audit/error paths: structured PlanError, not string parsing
# ---------------------------------------------------------------------------


def test_simulate_multi_audits_bad_plans_through_structured_errors():
    """A policy that plans an NPU decision past its deadline: the audit must
    flag it through ``PlanError.frame`` (simulate_multi consumes the
    structured field, never the message text) and count every round's bad
    frame as missed without crediting accuracy."""
    fleet = make_fleet(1, policy="local")
    stream = fleet[0].stream
    bad_plan = RoundPlan(
        decisions=[
            Decision(0, Where.NPU, 0, stream.r_max, start=0.0, finish=stream.deadline + 1.0)
        ],
        horizon=1,
        npu_busy_until=0.0,
    )
    # The structured surface itself: typed frame ids plus readable text.
    errors = validate_plan(bad_plan, gamma=stream.gamma, deadline=stream.deadline)
    assert errors, "deadline overrun must produce PlanErrors"
    assert {e.frame for e in errors} == {0}
    assert all(isinstance(e.frame, int) for e in errors)
    assert "deadline" in str(errors[0])

    fleet[0]._policy = lambda models, stream, net, npu_free=0.0: bad_plan
    sched = EdgeServerScheduler(fleet, policy="weighted_fair", capacity=2)
    ms = simulate_multi(sched, Trace.constant(6.0), 5)
    s = ms.per_client[0]
    assert s.frames_missed_deadline == 5
    assert s.frames_processed == 0
    assert s.accuracy_sum == 0.0
    # Non-strict mode skips plan validation: the bad plan is taken at face
    # value and credited (defence-in-depth is opt-out, but explicit).
    sched2 = EdgeServerScheduler(
        make_fleet(1, policy="local"), policy="weighted_fair", capacity=2
    )
    sched2.clients[0]._policy = lambda models, stream, net, npu_free=0.0: bad_plan
    ms2 = simulate_multi(sched2, Trace.constant(6.0), 5, strict=False)
    assert ms2.per_client[0].frames_missed_deadline == 0
    assert ms2.per_client[0].frames_processed == 5
