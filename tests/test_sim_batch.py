"""Golden equivalence for the vectorized sweep backend.

The contract under test (docs/simulation.md): for every registered
``batched=True`` policy, ``Session.run_sweep(grid, backend="batched")``
reproduces the reference simulator's audited per-scenario stats across a
>= 100-point grid that exercises window padding (mixed fps), bin padding
(mixed deadlines/grids), the infeasible horizon-1 path (deadline below
every NPU latency), and policy-param axes.  The jax_* planners are
**bit-identical** (same f32 kernels); the network-aware ``max_accuracy`` /
``max_utility`` planners replay float64 Python references, so their
certified contract is integer stats exact + accuracy sums within
``AUDIT_TOL`` — on constant AND piecewise traces.  Plus: registry flag <->
planner table sync, fleet-axis replication vs the real ``run_multi``,
fallback routing (incl. fleet grids of offload-capable batched policies),
the piecewise-base trace-override warning, and the sweep CLI.
"""
from __future__ import annotations

import json
import logging

import pytest

from repro.core import PolicySpec
from repro.core.audit import AUDIT_TOL
from repro.core.registry import available_policies, get_policy
from repro.core.sim_batch import batched_policies, simulate_batch
from repro.session import (
    FleetSpec,
    ScenarioSpec,
    Session,
    SweepGrid,
    SweepReport,
    TraceSpec,
)

# Every batched policy with (base params, the param axis swept in the golden
# grid).  test_registry_flag below fails if a policy registers batched=True
# without joining this table — new backends must enter the golden sweep.
BATCHED_PARAMS: dict[str, tuple[dict, dict]] = {
    "jax_accuracy": ({}, {"grid": (1e-3, 2e-3)}),
    "jax_utility": ({"alpha": 200.0}, {"alpha": (50.0, 200.0)}),
    "max_accuracy": ({}, {"grid": (1e-3, 2e-3)}),
    "max_utility": ({"alpha": 200.0}, {"alpha": (50.0, 200.0)}),
}

# The network-aware planners replay float64 Python DPs: integer stats must
# match exactly, accuracy sums within AUDIT_TOL (the jax_* planners stay
# bit-identical — tolerance 0).
NET_POLICIES = frozenset({"max_accuracy", "max_utility"})

INT_FIELDS = (
    "frames_processed",
    "frames_missed_deadline",
    "frames_offloaded",
    "frames_total",
    "schedule_calls",
)
STATS_FIELDS = ("accuracy_sum",) + INT_FIELDS

GOLD_FRAMES = 24

PIECEWISE = TraceSpec(
    kind="piecewise", points=((0.0, 3.0), (0.3, 0.8), (0.9, 6.0)), rtt_ms=60.0
)


def _golden_grid(param_axis: dict) -> SweepGrid:
    # 2 x 5 x 5 x 2 = 100 points; deadline 10 ms < min t_npu (17 ms) forces
    # the infeasible skip-all rounds, mixed fps forces window padding.
    return SweepGrid(
        bandwidth_mbps=(1.0, 2.5),
        deadline_ms=(10.0, 100.0, 150.0, 200.0, 350.0),
        fps=(10.0, 24.0, 30.0, 50.0, 60.0),
        params=param_axis,
    )


def _assert_points_equal(ref, bat, acc_tol: float = 0.0):
    assert len(ref.points) == len(bat.points)
    for pr, pb in zip(ref.points, bat.points):
        assert pr.overrides == pb.overrides
        assert len(pr.streams) == len(pb.streams)
        for sr, sb in zip(pr.streams, pb.streams):
            for f in INT_FIELDS:
                assert getattr(sr, f) == getattr(sb, f), (pr.overrides, f)
            assert abs(sr.accuracy_sum - sb.accuracy_sum) <= acc_tol, pr.overrides


def _acc_tol(name: str) -> float:
    return AUDIT_TOL if name in NET_POLICIES else 0.0


# Detect+track planners are batched too, but plan a different workload
# kind; their golden grids live in tests/test_tracking.py.
TRACK_POLICIES = frozenset(
    n for n in available_policies() if get_policy(n).workloads == ("track",)
)


def test_registry_flag_matches_backend_table():
    flagged = {n for n in available_policies() if get_policy(n).batched}
    assert set(batched_policies()) == flagged
    # new batched classify policies join this sweep; track ones join
    # test_tracking.py's (TRACK_POLICIES is derived, so neither can hide)
    assert set(BATCHED_PARAMS) | TRACK_POLICIES == flagged
    assert not (set(BATCHED_PARAMS) & TRACK_POLICIES)


@pytest.mark.slow
@pytest.mark.parametrize("name", sorted(BATCHED_PARAMS))
def test_batched_backend_matches_reference_exactly(name):
    base_params, axis = BATCHED_PARAMS[name]
    grid = _golden_grid(axis)
    assert len(grid) >= 100
    spec = ScenarioSpec(policy=PolicySpec(name, base_params), n_frames=GOLD_FRAMES)
    ref = Session(spec).run_sweep(grid, backend="reference")
    bat = Session(spec).run_sweep(grid, backend="batched")
    assert ref.backend == "reference" and bat.backend == "batched"
    assert len(bat.points) == len(grid)
    _assert_points_equal(ref, bat, acc_tol=_acc_tol(name))


@pytest.mark.slow
@pytest.mark.parametrize("name", sorted(NET_POLICIES))
def test_network_planners_match_reference_on_piecewise_traces(name):
    """The paper's planners under a time-varying trace: bandwidth steps
    across segment boundaries mid-stream, an rtt axis varies the offload
    budget, and a 10 ms deadline forces the skip path — the batched
    stats must still match the reference loop point for point."""
    base_params, _ = BATCHED_PARAMS[name]
    grid = SweepGrid(
        deadline_ms=(10.0, 150.0, 200.0, 350.0),
        fps=(10.0, 30.0, 60.0),
        rtt_ms=(40.0, 100.0),
    )
    spec = ScenarioSpec(
        policy=PolicySpec(name, base_params), n_frames=36, trace=PIECEWISE
    )
    ref = Session(spec).run_sweep(grid, backend="reference")
    bat = Session(spec).run_sweep(grid, backend="batched")
    assert bat.backend == "batched"
    _assert_points_equal(ref, bat, acc_tol=AUDIT_TOL)


@pytest.mark.parametrize("name", sorted(NET_POLICIES))
def test_network_planners_small_constant_and_piecewise(name):
    """Fast-lane cousin of the slow goldens: a handful of points on both
    trace kinds, asserting the same equivalence contract."""
    base_params, _ = BATCHED_PARAMS[name]
    for trace in (TraceSpec(mbps=2.5), PIECEWISE):
        spec = ScenarioSpec(
            policy=PolicySpec(name, base_params), n_frames=16, trace=trace
        )
        grid = SweepGrid(deadline_ms=(150.0, 250.0), fps=(30.0,))
        ref = Session(spec).run_sweep(grid, backend="reference")
        bat = Session(spec).run_sweep(grid, backend="batched")
        assert bat.backend == "batched"
        _assert_points_equal(ref, bat, acc_tol=AUDIT_TOL)
        # the planners really do offload under a healthy network
        if trace.kind == "constant":
            assert any(p.stats.frames_offloaded > 0 for p in bat.points)


def test_infeasible_deadline_is_skip_not_miss():
    """Deadline below every NPU latency: the reference emits horizon-1 SKIP
    rounds (no processing, no deadline misses, one schedule call per frame);
    the batched backend must reproduce that path, not approximate it."""
    spec = ScenarioSpec(policy=PolicySpec("jax_accuracy"), n_frames=12)
    rep = Session(spec).run_sweep(SweepGrid(deadline_ms=(10.0,)), backend="batched")
    st = rep.points[0].stats
    assert st.frames_processed == 0
    assert st.frames_missed_deadline == 0
    assert st.schedule_calls == 12  # one skip round per frame


def test_fleet_axis_replication_matches_run_multi():
    grid = SweepGrid(n_clients=(1, 3))
    spec = ScenarioSpec(
        policy=PolicySpec("jax_utility", {"alpha": 200.0}),
        n_frames=GOLD_FRAMES,
        fleet=FleetSpec(capacity=2),
    )
    ref = Session(spec).run_sweep(grid, backend="reference")
    bat = Session(spec).run_sweep(grid, backend="batched")
    _assert_points_equal(ref, bat)
    assert [len(p.streams) for p in bat.points] == [1, 3]
    # Local-only planners now route through the fleet engine's single-lane
    # backend (one lane per scenario, stats replicated per client) instead
    # of the old post-hoc replication; the scheduler audit comes along.
    assert bat.meta["engine"] == "sim_multi_batch"
    # Local-only plans still *request* bandwidth each round in the
    # reference, so the statically reconstructed audit must agree.
    for pr, pb in zip(ref.points, bat.points):
        assert pb.meta["grants"] == pr.meta["grants"]
        assert pb.meta["denials"] == pr.meta["denials"]
    assert all(s.frames_offloaded == 0 for s in bat.points[1].streams)


def test_width_axis_partitions_exactly():
    grid = SweepGrid(fps=(20.0, 50.0), params={"width": (16, 64)})
    spec = ScenarioSpec(policy=PolicySpec("jax_utility", {"alpha": 120.0}), n_frames=18)
    ref = Session(spec).run_sweep(grid, backend="reference")
    bat = Session(spec).run_sweep(grid, backend="batched")
    _assert_points_equal(ref, bat)


def test_large_width_still_supported():
    """The registry puts no upper bound on the Pareto-front width; the sort
    rewrite must not impose one (regression: a packed-payload variant once
    asserted on width > 1024)."""
    spec = ScenarioSpec(
        policy=PolicySpec("jax_utility", {"alpha": 200.0, "width": 2048}), n_frames=6
    )
    ref = Session(spec).run_sweep(SweepGrid(), backend="reference")
    bat = Session(spec).run_sweep(SweepGrid(), backend="batched")
    _assert_points_equal(ref, bat)
    assert ref.points[0].stats.frames_processed > 0


def test_python_policy_falls_back_with_warning(caplog):
    spec = ScenarioSpec(policy=PolicySpec("local"), n_frames=6)
    with caplog.at_level(logging.WARNING, logger="repro.session"):
        rep = Session(spec).run_sweep(SweepGrid(bandwidth_mbps=(2.5,)), backend="batched")
    assert rep.backend == "reference"
    assert "fallback" in rep.meta
    assert any("no batched backend" in r.getMessage() for r in caplog.records)
    # auto-routing picks reference silently for Python-only policies
    caplog.clear()
    with caplog.at_level(logging.WARNING, logger="repro.session"):
        auto = Session(spec).run_sweep(SweepGrid(bandwidth_mbps=(2.5,)))
    assert auto.backend == "reference" and not caplog.records


def test_simulate_batch_rejects_unbatched_policy():
    with pytest.raises(ValueError, match="no batched backend"):
        simulate_batch("local", [], [])


@pytest.mark.parametrize("name", sorted(NET_POLICIES))
def test_offloading_policy_fleet_grid_routes_to_fleet_engine(name, caplog):
    """Fleet grids of max_accuracy/max_utility used to log a documented
    fallback (contention made per-client replication wrong, and no fleet
    planner existed).  The dedicated fleet planners now serve them batched:
    no fallback warning, ``meta["engine"]`` confirms the engine, and the
    stats match the reference event loop."""
    base_params, _ = BATCHED_PARAMS[name]
    spec = ScenarioSpec(
        policy=PolicySpec(name, base_params), n_frames=8,
        fleet=FleetSpec(n_clients=2, capacity=2),
    )
    grid = SweepGrid(n_clients=(1, 2))
    with caplog.at_level(logging.WARNING, logger="repro.session"):
        rep = Session(spec).run_sweep(grid, backend="batched")
    assert rep.backend == "batched"
    assert rep.meta["engine"] == "sim_multi_batch"
    assert "fallback" not in rep.meta
    assert not any("falling back" in r.getMessage() for r in caplog.records)
    ref = Session(spec).run_sweep(grid, backend="reference")
    _assert_points_equal(ref, rep)
    assert [len(p.streams) for p in rep.points] == [1, 2]


def test_utility_fast_width_overflow_rerun_is_exact(monkeypatch):
    """The Max-Utility planner first runs a narrow Pareto width and reruns
    lanes whose fronts outgrow it at the reference cap.  Force the narrow
    pass to overflow on every round (width 2) and check the spliced results
    still match the reference loop — the fast path must never trade
    exactness."""
    import repro.core.sim_batch as sb

    monkeypatch.setattr(sb, "_UTIL_FAST_WIDTH", 2)
    spec = ScenarioSpec(
        policy=PolicySpec("max_utility", {"alpha": 200.0}), n_frames=12
    )
    grid = SweepGrid(deadline_ms=(200.0, 350.0), fps=(30.0,))
    ref = Session(spec).run_sweep(grid, backend="reference")
    bat = Session(spec).run_sweep(grid, backend="batched")
    assert bat.backend == "batched"
    _assert_points_equal(ref, bat, acc_tol=AUDIT_TOL)
    assert any(p.stats.frames_processed > 0 for p in bat.points)


def test_utility_prune_epsilon_window_matches_reference():
    """The reference's dominance bar is the last KEPT utility; candidates
    rejected inside the 1e-12 epsilon must not raise it.  NPU accuracies
    separated at the 13th decimal make candidate utilities collide within
    the epsilon — a cummax-based prune drops front entries the reference
    keeps (regression for the keep-fold in _utility_dp64)."""
    from repro.core import StreamSpec, Trace, profile_ms, simulate
    from repro.core.sim_batch import BatchScenario, simulate_batch

    models = [
        profile_ms(n, t_npu_ms=20.0, t_server_ms=9.0,
                   acc_server={45: 0.2, 224: 0.6}, acc_npu={224: a})
        for n, a in (("a", 0.5), ("b", 0.5 + 4e-13), ("c", 0.5 + 1.1e-12))
    ]
    spec = PolicySpec("max_utility", {"alpha": 200.0})
    for fps, dl, n in ((30.0, 0.2, 18), (50.0, 0.35, 24), (10.0, 0.1, 12)):
        stream = StreamSpec(fps=fps, deadline=dl)
        got, = simulate_batch(
            "max_utility", models,
            [BatchScenario(stream=stream, n_frames=n, params=spec.resolved)],
        )
        ref = simulate(spec.build(), models, stream, Trace.constant(2.5), n)
        for f in INT_FIELDS:
            assert getattr(got, f) == getattr(ref, f), (fps, dl, n, f)
        assert abs(got.accuracy_sum - ref.accuracy_sum) <= AUDIT_TOL


def test_utility_dp64_overflow_flag():
    """White-box: a width too small for the front sets the overflow flag;
    the reference cap width does not (for this instance)."""
    import jax.numpy as jnp
    from jax.experimental import enable_x64

    from repro.core.jax_sched import _utility_dp64
    from repro.core.profiles import PAPER_MODELS

    with enable_x64():
        t_npu = jnp.array([m.t_npu for m in PAPER_MODELS], jnp.float64)
        acc = jnp.array(
            [m.acc_npu[max(m.acc_npu)] for m in PAPER_MODELS], jnp.float64
        )
        kw = dict(
            n_frames=8, gamma=jnp.float64(1 / 30.0), deadline=jnp.float64(0.35),
            alpha=jnp.float64(200.0), npu_free=jnp.float64(0.0),
            first_arrival=jnp.float64(0.0), window=jnp.float64(8 / 30.0),
        )
        *_, ov_small = _utility_dp64(t_npu, acc, 8, width=2, **kw)
        *_, ov_large = _utility_dp64(t_npu, acc, 8, width=256, **kw)
    assert bool(ov_small) and not bool(ov_large)


def test_bandwidth_axis_overriding_piecewise_trace_warns_and_records(caplog):
    """A bandwidth_mbps axis replaces the base trace; on a piecewise base
    that silently drops the time-varying profile — run_sweep must log a
    warning and record the override in the affected points' meta."""
    spec = ScenarioSpec(policy=PolicySpec("jax_accuracy"), n_frames=6, trace=PIECEWISE)
    grid = SweepGrid(bandwidth_mbps=(1.0, 2.5), deadline_ms=(200.0,))
    with caplog.at_level(logging.WARNING, logger="repro.session"):
        rep = Session(spec).run_sweep(grid)
    assert any(
        "piecewise base trace" in r.getMessage() for r in caplog.records
    ), "silent trace override must warn"
    assert all("trace_override" in p.meta for p in rep.points)
    assert "bandwidth_mbps" in rep.points[0].meta["trace_override"]
    # constant base trace: the axis is the normal parameterization — silent
    caplog.clear()
    spec_c = ScenarioSpec(policy=PolicySpec("jax_accuracy"), n_frames=6)
    with caplog.at_level(logging.WARNING, logger="repro.session"):
        rep_c = Session(spec_c).run_sweep(grid)
    assert not caplog.records
    assert all("trace_override" not in p.meta for p in rep_c.points)
    # an rtt_ms-only axis preserves the piecewise profile: no override
    caplog.clear()
    with caplog.at_level(logging.WARNING, logger="repro.session"):
        rep_r = Session(spec).run_sweep(SweepGrid(rtt_ms=(50.0, 100.0)))
    assert not caplog.records
    assert all("trace_override" not in p.meta for p in rep_r.points)


def test_sweep_grid_validation_and_points():
    grid = SweepGrid(bandwidth_mbps=(1.0, 2.0), params={"alpha": (50.0,)})
    assert len(grid) == 2
    assert grid.points()[0] == {"bandwidth_mbps": 1.0, "alpha": 50.0}
    assert len(SweepGrid()) == 1 and SweepGrid().points() == [{}]
    with pytest.raises(ValueError, match="shadows a scenario axis"):
        SweepGrid(params={"fps": (30.0,)})
    with pytest.raises(ValueError, match="is empty"):
        SweepGrid(params={"alpha": ()})
    with pytest.raises(ValueError, match="unknown SweepGrid axes"):
        SweepGrid.from_json({"bandwidth": [1.0]})
    # scalars and strings are rejected, not silently iterated ("fifo" must
    # not become the 4-point axis ('f','i','f','o'))
    with pytest.raises(ValueError, match="must be a list"):
        SweepGrid.from_json({"bandwidth_mbps": 2.5})
    with pytest.raises(ValueError, match="must be a list"):
        SweepGrid(allocation="fifo")
    with pytest.raises(ValueError, match="must be a list"):
        SweepGrid(params={"alpha": "50"})
    with pytest.raises(ValueError, match="params must be a mapping"):
        SweepGrid.from_json({"params": [50.0]})
    rt = SweepGrid.from_json(json.loads(json.dumps(grid.to_json())))
    assert rt == grid


def test_unknown_backend_rejected():
    spec = ScenarioSpec(policy=PolicySpec("local"), n_frames=6)
    with pytest.raises(ValueError, match="unknown backend"):
        Session(spec).run_sweep(SweepGrid(), backend="warp")


def test_n_clients_axis_rejects_per_client_vectors():
    spec = ScenarioSpec(
        policy=PolicySpec("local"),
        n_frames=6,
        fleet=FleetSpec(n_clients=2, weights=(1.0, 2.0)),
    )
    with pytest.raises(ValueError, match="cannot resize"):
        Session(spec).run_sweep(SweepGrid(n_clients=(1, 2)))


def test_sweep_report_json_round_trip_batched():
    spec = ScenarioSpec(policy=PolicySpec("jax_accuracy"), n_frames=12, label="rt")
    rep = Session(spec).run_sweep(SweepGrid(deadline_ms=(150.0, 200.0)))
    rt = SweepReport.from_json(json.loads(json.dumps(rep.to_json())))
    assert rt == rep


def test_sweep_cli_smoke(tmp_path, capsys):
    from repro.session import main

    spec_file = tmp_path / "scenario.json"
    grid_file = tmp_path / "grid.json"
    spec = ScenarioSpec(policy=PolicySpec("local"), n_frames=6, label="cli-sweep")
    spec_file.write_text(json.dumps(spec.to_json()))
    grid_file.write_text(json.dumps(SweepGrid(bandwidth_mbps=(1.0, 2.5)).to_json()))
    assert main(["sweep", str(spec_file), "--grid", str(grid_file)]) == 0
    report = SweepReport.from_json(json.loads(capsys.readouterr().out))
    assert len(report) == 2 and report.base.label == "cli-sweep"

    out_file = tmp_path / "report.json"
    assert main([
        "sweep", str(spec_file), "--grid", str(grid_file), "--out", str(out_file),
    ]) == 0
    assert "2 points via reference backend" in capsys.readouterr().out
    saved = SweepReport.from_json(out_file.read_text())
    assert [p.overrides for p in saved] == [p.overrides for p in report]
    assert [p.stats.accuracy_sum for p in saved] == [p.stats.accuracy_sum for p in report]

    grid_file.write_text('{"bandwidth": [1.0]}')  # unknown axis
    assert main(["sweep", str(spec_file), "--grid", str(grid_file)]) == 2
    err = capsys.readouterr().err
    assert err.startswith("error:") and "Traceback" not in err

    grid_file.write_text('{"bandwidth_mbps": 2.5}')  # scalar axis
    assert main(["sweep", str(spec_file), "--grid", str(grid_file)]) == 2
    err = capsys.readouterr().err
    assert "must be a list" in err and "Traceback" not in err

    # malformed payload shapes that raise TypeError deep in from_json still
    # honor the one-line contract
    grid_file.write_text(json.dumps(SweepGrid(bandwidth_mbps=(1.0,)).to_json()))
    spec_file.write_text('{"policy": {"name": "local"}, "models": 5}')
    assert main(["sweep", str(spec_file), "--grid", str(grid_file)]) == 2
    err = capsys.readouterr().err
    assert err.startswith("error:") and "Traceback" not in err

    # unwritable --out is the same one-line error contract, not a traceback
    grid_file.write_text(json.dumps(SweepGrid(bandwidth_mbps=(1.0,)).to_json()))
    assert main([
        "sweep", str(spec_file), "--grid", str(grid_file),
        "--out", str(tmp_path / "no" / "such" / "dir" / "r.json"),
    ]) == 2
    err = capsys.readouterr().err
    assert err.startswith("error:") and "Traceback" not in err
