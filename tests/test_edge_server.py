"""Multi-stream edge-server tests: bandwidth-sharing invariants, graceful
degradation under saturation, and batched-endpoint numerics.

Covers the three acceptance properties of the multi-tenant subsystem:
  * per-client bandwidth grants never oversubscribe the trace bandwidth;
  * when the edge is saturated every client falls back to its local NPU plan
    (and matches the single-stream Local policy exactly);
  * the batched serving endpoint returns the same logits as per-frame calls.
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.core import (
    EdgeServerScheduler,
    Trace,
    make_fleet,
    make_policy,
    network_mbps,
    simulate,
    simulate_multi,
)
from repro.core.profiles import PAPER_MODELS, PAPER_STREAM
from repro.core.simulator import _Upload, _fluid_rates

N_FRAMES = 30


def _run(n, policy, *, mbps=12.0, capacity=4, frames=N_FRAMES, **fleet_kw):
    sched = EdgeServerScheduler(make_fleet(n, **fleet_kw), policy=policy, capacity=capacity)
    return sched, simulate_multi(sched, Trace.constant(mbps), frames)


# ---------------------------------------------------------------------------
# Bandwidth-sharing invariants
# ---------------------------------------------------------------------------

def test_concurrent_grants_never_exceed_trace_bandwidth():
    sched, ms = _run(4, "weighted_fair", mbps=12.0)
    assert sum(s.frames_offloaded for s in ms.per_client) > 0  # offloads happened
    assert sched.audit.max_concurrent_bps <= 12e6 + 1e-6


def test_allocate_respects_static_weighted_shares():
    B = network_mbps(10.0)
    fleet = make_fleet(4, weights=[3.0, 1.0, 1.0, 1.0])
    sched = EdgeServerScheduler(fleet, policy="weighted_fair", capacity=4)
    grants = [sched.allocate(c.client_id, 0.0, B) for c in fleet]
    # Static share bound: B * w_i / sum(w), never more.
    for g, c in zip(grants, fleet):
        assert g <= 10e6 * c.weight / 6.0 + 1e-6
    assert grants[0] == pytest.approx(3.0 * grants[1], rel=1e-9)


def test_fifo_grants_whole_link_to_everyone():
    B = network_mbps(5.0)
    fleet = make_fleet(3)
    sched = EdgeServerScheduler(fleet, policy="fifo", capacity=1)
    for c in fleet:
        assert sched.allocate(c.client_id, 0.0, B) == pytest.approx(5e6)


def test_fluid_rates_waterfilling():
    def up(weight, cap):
        return _Upload(0, 1.0, weight, cap, 0.0, 0.0, 0.0, 0.0)

    # Caps sum below B: everyone transmits at cap (coordinated case).
    rates = _fluid_rates(10e6, [up(1, 3e6), up(1, 4e6)])
    assert rates == pytest.approx([3e6, 4e6])
    # Infinite caps: weighted processor sharing (fifo case).
    rates = _fluid_rates(9e6, [up(2, float("inf")), up(1, float("inf"))])
    assert rates == pytest.approx([6e6, 3e6])
    # One capped flow returns its leftover to the uncapped one.
    rates = _fluid_rates(10e6, [up(1, 1e6), up(1, float("inf"))])
    assert rates == pytest.approx([1e6, 9e6])
    assert sum(rates) <= 10e6 + 1e-6


# ---------------------------------------------------------------------------
# Graceful degradation under saturation
# ---------------------------------------------------------------------------

def test_saturated_edge_degrades_to_pure_local():
    """capacity=0: every offload is denied; each client must match the
    single-stream Local policy exactly (same DP, no deadline misses)."""
    sched, ms = _run(3, "weighted_fair", capacity=0)
    local = simulate(
        make_policy("local"), list(PAPER_MODELS), PAPER_STREAM, Trace.constant(12.0), N_FRAMES
    )
    for s in ms.per_client:
        assert s.frames_offloaded == 0
        assert s.frames_missed_deadline == 0
        assert s.frames_processed == local.frames_processed
        assert s.accuracy_sum == pytest.approx(local.accuracy_sum)
    assert sched.audit.denials > 0 and sched.audit.grants == 0


def test_zero_bandwidth_runs_all_local_without_hanging():
    _, ms = _run(2, "weighted_fair", mbps=0.0)
    for s in ms.per_client:
        assert s.frames_offloaded == 0
        assert s.frames_processed > 0


def test_miss_rate_stays_bounded_as_fleet_grows():
    for n in (1, 2, 4):
        _, ms = _run(n, "weighted_fair", mbps=6.0)
        assert ms.max_miss_rate <= 0.10, f"miss rate blew up at n={n}"


def test_weighted_fair_beats_naive_fifo_under_contention():
    _, wf = _run(2, "weighted_fair", mbps=6.0)
    _, fifo = _run(2, "fifo", mbps=6.0)
    assert wf.aggregate_accuracy > fifo.aggregate_accuracy
    assert wf.max_miss_rate <= fifo.max_miss_rate


def test_priority_clients_keep_the_edge_when_slots_are_scarce():
    sched, ms = _run(4, "priority", capacity=1, priorities=[0, 0, 2, 2])
    low = sum(ms.per_client[i].frames_offloaded for i in (0, 1))
    high = sum(ms.per_client[i].frames_offloaded for i in (2, 3))
    assert high > 0
    assert low == 0
    # Denied clients still process frames locally at full rate.
    for i in (0, 1):
        assert ms.per_client[i].frames_processed == N_FRAMES


# ---------------------------------------------------------------------------
# Batched endpoint numerics
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def toy_endpoint():
    import jax.numpy as jnp

    from repro.serving import BatchedEndpoint

    W = jnp.asarray(
        np.random.default_rng(0).standard_normal((4 * 4 * 3, 10)).astype(np.float32)
    )

    def forward(x):
        return jnp.tanh(x).reshape(x.shape[0], -1) @ W

    ep = BatchedEndpoint("toy", forward, max_batch=8)
    ep.warmup(np.zeros((4, 4, 3), np.float32))
    return ep


def test_batched_endpoint_matches_per_frame(toy_endpoint):
    frames = np.random.default_rng(1).standard_normal((11, 4, 4, 3)).astype(np.float32)
    batched = toy_endpoint(frames)  # 11 -> buckets 8 + 4(pad 1)
    single = np.concatenate([toy_endpoint(frames[i : i + 1]) for i in range(len(frames))])
    np.testing.assert_allclose(batched, single, atol=1e-5)


def test_edge_batch_server_coalesces_and_routes(toy_endpoint):
    from repro.serving import EdgeBatchServer, OffloadRequest

    frames = np.random.default_rng(2).standard_normal((6, 4, 4, 3)).astype(np.float32)
    server = EdgeBatchServer({0: toy_endpoint})
    flushes_before = toy_endpoint.stats.flushes
    for cid in range(3):
        for f in range(2):
            server.submit(OffloadRequest(cid, f, 0, frames[cid * 2 + f]))
    assert server.pending() == 6
    out = server.flush()
    assert server.pending() == 0
    assert toy_endpoint.stats.flushes == flushes_before + 1  # ONE forward for all 6
    for cid in range(3):
        for f in range(2):
            expect = toy_endpoint(frames[cid * 2 + f][None])[0]
            np.testing.assert_allclose(out[(cid, f)], expect, atol=1e-5)


def test_batched_endpoint_counts_flush_per_forward(toy_endpoint):
    """A batch larger than max_batch splits into chunks; each chunk is its
    own jitted forward and must count as its own flush, or mean_batch /
    pad_fraction overstate batching efficiency."""
    frames = np.random.default_rng(3).standard_normal((20, 4, 4, 3)).astype(np.float32)
    before_flushes = toy_endpoint.stats.flushes
    before_frames = toy_endpoint.stats.frames
    before_padded = toy_endpoint.stats.padded
    out = toy_endpoint(frames)  # max_batch=8 -> chunks 8 + 8 + 4(pad 0)
    assert out.shape[0] == 20
    assert toy_endpoint.stats.flushes == before_flushes + 3
    assert toy_endpoint.stats.frames == before_frames + 20
    assert toy_endpoint.stats.padded == before_padded + 0
    # Odd-sized tail still pads to its bucket — and still counts per forward.
    before_flushes = toy_endpoint.stats.flushes
    toy_endpoint(frames[:11])  # chunks 8 + 3(pad to 4)
    assert toy_endpoint.stats.flushes == before_flushes + 2
    assert toy_endpoint.stats.padded == before_padded + 1


def test_edge_batch_server_rejects_unknown_model(toy_endpoint):
    from repro.serving import EdgeBatchServer, OffloadRequest

    server = EdgeBatchServer({0: toy_endpoint})
    with pytest.raises(KeyError):
        server.submit(OffloadRequest(0, 0, 99, np.zeros((4, 4, 3), np.float32)))
