"""Elastic-restart integration: train, 'lose' devices, re-plan the mesh,
restore the checkpoint under the new plan, and continue deterministically —
the full 1000-node failure story at test scale."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ck
from repro import configs
from repro.arch import ShapeSpec
from repro.data import DataSpec, SyntheticStream
from repro.launch import steps
from repro.runtime import plan_elastic_remesh
from repro.train.optim import AdamWConfig


@pytest.mark.slow
def test_fail_replan_restore_continue(tmp_path):
    a = configs.get("resnet-50", smoke=True)
    a = dataclasses.replace(a, shapes=(ShapeSpec("t", "classify_train", 4, img=32),))
    prog = steps.build_cell(a, "t", adamw=AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=20))
    step = prog.jit()
    stream = SyntheticStream(DataSpec(a, a.shape("t"), seed=0))

    ts = prog.init_args(jax.random.key(0))[0]
    losses = []
    for i in range(6):
        ts, m = step(ts, {k: jnp.asarray(v) for k, v in stream.batch_at(i).items()})
        losses.append(float(m["loss"]))
        if i == 3:
            ck.save(tmp_path, 4, ts)  # checkpoint after step index 3

    # --- pod failure: 512 -> 300 surviving chips ---
    plan = plan_elastic_remesh(300)
    assert plan.mesh_shape == (18, 16)  # model axis preserved
    assert plan.data_parallel_scale < 1.0  # driver raises grad-accum by 1/scale

    # --- restart path: restore under (new) shardings and continue ---
    last = ck.latest_step(tmp_path)
    assert last == 4
    like = prog.init_args(jax.random.key(0))[0]
    shardings = jax.tree.map(lambda x: None, like)
    ts2, _ = ck.restore_resharded(tmp_path, last, like, shardings)
    for i in range(4, 6):  # deterministic skip-ahead re-runs the same batches
        ts2, m = step(ts2, {k: jnp.asarray(v) for k, v in stream.batch_at(i).items()})
    # same trajectory as the uninterrupted run
    assert float(m["loss"]) == pytest.approx(losses[-1], rel=1e-5)


def test_remesh_scale_measured_against_one_pod_prior():
    """The scale must be measured against the mesh the cluster actually ran,
    not a hardwired two-pod history: a one-pod cluster losing half its chips
    halves DP, it does not quarter it."""
    plan = plan_elastic_remesh(128, prior_chips=256)
    assert plan.mesh_shape == (8, 16)
    assert plan.data_parallel_scale == pytest.approx(8 / 16)


def test_remesh_default_prior_is_the_two_pod_cluster():
    plan = plan_elastic_remesh(300)
    assert plan.mesh_shape == (18, 16)
    assert plan.data_parallel_scale == pytest.approx(18 / 32)


def test_remesh_scale_against_four_pod_prior():
    plan = plan_elastic_remesh(512, prior_chips=1024)
    assert plan.mesh_shape == (2, 16, 16)
    assert plan.data_parallel_scale == pytest.approx(32 / 64)


def test_remesh_rejects_invalid_prior():
    with pytest.raises(ValueError, match="prior cluster invalid"):
        plan_elastic_remesh(32, prior_chips=8)


def test_mitigation_for_unknown_worker_is_observe():
    """Asking about a worker with no timing data must not KeyError — the
    decision is to gather samples first."""
    from repro.runtime.fault_tolerance import StragglerMitigator

    mit = StragglerMitigator()
    assert mit.mitigation("ghost") == "observe"
    mit.observe("w0", 1.0)
    assert mit.mitigation("ghost") == "observe"  # still unknown
    assert mit.mitigation("w0") in ("rebalance_input", "replace")


def test_register_does_not_resurrect_dead_workers():
    """Re-registering a DEAD worker is a membership no-op: only a real
    heartbeat proves liveness again."""
    from repro.runtime.fault_tolerance import HeartbeatMonitor, WorkerState

    now = 0.0
    mon = HeartbeatMonitor(
        interval_s=1.0, suspect_after=2.0, dead_after=4.0, clock=lambda: now
    )
    mon.register("w")
    now = 10.0
    assert mon.sweep() == {"w": WorkerState.DEAD}
    mon.register("w")  # a restarted host re-announcing itself
    assert mon.workers["w"].state is WorkerState.DEAD
    assert mon.dead() == ["w"]
    mon.beat("w")  # the one legitimate resurrection path
    assert mon.workers["w"].state is WorkerState.HEALTHY
    assert mon.dead() == []


def test_controller_reacts_to_edge_pool_failure():
    """FastVA tie-in: when the edge pool dies (t_server -> inf), the policies
    route everything to the NPU path and keep meeting deadlines."""
    from repro.core import PAPER_MODELS, PAPER_STREAM, Trace, make_policy, simulate
    from repro.core.profiles import ModelProfile

    dead_edge = [
        ModelProfile(m.name, m.t_npu, float("inf"), m.acc_server, m.acc_npu)
        for m in PAPER_MODELS
    ]
    st = simulate(make_policy("max_accuracy"), dead_edge, PAPER_STREAM, Trace.constant(3.0), 60)
    assert st.frames_processed == 60
    assert st.frames_missed_deadline == 0
    # all-local accuracy == the Local baseline's
    st_local = simulate(make_policy("local"), dead_edge, PAPER_STREAM, Trace.constant(3.0), 60)
    assert st.mean_accuracy == pytest.approx(st_local.mean_accuracy, abs=1e-9)
