"""Property tests for the tracking workload, on random inputs.

The load-bearing invariant is staleness monotonicity: a tracked frame can
never score more than a fresher one.  It is pinned twice —

  * on the scoring tables every backend consumes (``retention_powers`` /
    ``interval_means``; the planners' minimal-feasible-k reduction is only
    correct because the interval mean is non-increasing);
  * end-to-end through the reference executor: with a fixed plan sequence
    (``track_fixed`` plans never read the workload truth), a faster-decaying
    world can only lower the executed accuracy sum.
"""
from __future__ import annotations

import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (requirements-dev.txt)"
)
import hypothesis.strategies as st  # noqa: E402
from hypothesis import given, settings  # noqa: E402

from repro.core import PolicySpec, StreamSpec, Trace, simulate  # noqa: E402
from repro.core.audit import AUDIT_TOL  # noqa: E402
from repro.core.profiles import PAPER_MODELS  # noqa: E402
from repro.core.tracking import (  # noqa: E402
    WorkloadSpec,
    interval_means,
    retention,
    retention_powers,
)

# Example counts come from the shared profiles in conftest.py
# (HYPOTHESIS_PROFILE=ci|nightly); settings() snapshots the active profile.
SETTINGS = settings()

MODELS = list(PAPER_MODELS)

INT_FIELDS = (
    "frames_processed",
    "frames_missed_deadline",
    "frames_offloaded",
    "frames_total",
    "schedule_calls",
)


@SETTINGS
@given(
    decay=st.floats(0.0, 1.0),
    density=st.floats(0.0, 8.0),
    det_acc=st.floats(0.0, 1.0),
)
def test_tracked_accuracy_monotone_in_staleness(decay, density, det_acc):
    ret = retention(decay, density)
    assert 0.0 <= ret <= 1.0
    scores = [det_acc * p for p in retention_powers(ret, 32)]
    assert all(a >= b for a, b in zip(scores, scores[1:]))
    means = interval_means(ret, 16)
    assert all(a >= b - 1e-15 for a, b in zip(means, means[1:]))


@SETTINGS
@given(decay=st.floats(0.0, 0.9), k=st.integers(1, 8))
def test_executed_accuracy_monotone_in_decay(decay, k):
    spec = PolicySpec("track_fixed", {"k": k})
    trace = Trace.constant(4.0)
    base = simulate(
        spec.build(), MODELS, StreamSpec(), trace, 12,
        workload=WorkloadSpec("track", decay=decay),
    )
    worse = simulate(
        spec.build(), MODELS, StreamSpec(), trace, 12,
        workload=WorkloadSpec("track", decay=min(decay + 0.1, 1.0)),
    )
    assert worse.accuracy_sum <= base.accuracy_sum + AUDIT_TOL
    # ...and the decay curve only rescales scores — the audited plan
    # execution (counts, misses, offloads) is identical.
    for f in INT_FIELDS:
        assert getattr(worse, f) == getattr(base, f)
