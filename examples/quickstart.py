"""Quickstart: the FastVA scheduler in 30 lines.

Plans one round of video-frame scheduling with the paper's Table II profiles,
then replays 90 frames through the audited simulator and prints what each
policy achieves.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.core import (  # noqa: E402
    PAPER_MODELS,
    PAPER_STREAM,
    Trace,
    make_policy,
    network_mbps,
    simulate,
)
from repro.core.max_accuracy import plan_round  # noqa: E402

net = network_mbps(2.5, rtt_ms=100)
plan = plan_round(list(PAPER_MODELS), PAPER_STREAM, net)
print("One Max-Accuracy round @2.5 Mbps (frame, where, model, resolution):")
for d in plan.decisions:
    print(f"  frame {d.frame}: {d.where.value:6s} model={d.model} r={d.resolution} "
          f"finish={d.finish*1e3:.0f} ms")

print("\n90-frame replay, mean accuracy per policy:")
for policy in ("max_accuracy", "local", "offload", "deepdecision"):
    stats = simulate(make_policy(policy), list(PAPER_MODELS), PAPER_STREAM,
                     Trace.constant(2.5), 90)
    print(f"  {policy:14s} acc={stats.mean_accuracy:.3f} "
          f"processed={stats.frames_processed}/90 "
          f"sched={stats.schedule_time/max(stats.schedule_calls,1)*1e6:.0f} us/round")
