"""Quickstart: the FastVA scheduler in 30 lines.

Plans one round of video-frame scheduling with the paper's Table II profiles,
then replays 90 frames through the audited simulator — every policy built by
name from the registry, every run described by one declarative ScenarioSpec.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.core import PAPER_MODELS, PAPER_STREAM, PolicySpec, network_mbps  # noqa: E402
from repro.core.registry import get_policy  # noqa: E402
from repro.session import ScenarioSpec, Session, TraceSpec  # noqa: E402

net = network_mbps(2.5, rtt_ms=100)
plan = PolicySpec("max_accuracy").build()(list(PAPER_MODELS), PAPER_STREAM, net, npu_free=0.0)
print("One Max-Accuracy round @2.5 Mbps (frame, where, model, resolution):")
for d in plan.decisions:
    print(f"  frame {d.frame}: {d.where.value:6s} model={d.model} r={d.resolution} "
          f"finish={d.finish*1e3:.0f} ms")

print("\n90-frame replay, mean accuracy per policy:")
for policy in ("max_accuracy", "local", "offload", "deepdecision", "brute_force"):
    spec = ScenarioSpec(policy=PolicySpec(policy), n_frames=90, trace=TraceSpec(mbps=2.5))
    stats = Session(spec).run_sim().stats
    print(f"  {policy:14s} acc={stats.mean_accuracy:.3f} "
          f"processed={stats.frames_processed}/90 "
          f"sched={stats.schedule_time/max(stats.schedule_calls,1)*1e6:.0f} us/round")

print("\nRegistered policies (see docs/api.md):")
for name in ("max_accuracy", "max_utility"):
    entry = get_policy(name)
    params = ", ".join(p.name + ("" if p.required else "?") for p in entry.params) or "-"
    print(f"  {name:14s} params: {params}")
