"""Edge-server demo: a fleet of phones sharing one uplink and one edge box.

Part 1 — scheduling: four clients contend for a 12 Mbps uplink.  The
coordinated weighted-fair scheduler splits the link and the server's worker
slots; every client keeps its deadline-miss rate at ~0 by degrading to its
local NPU plan whenever its share is too small to offload.  The naive FIFO
baseline (every client assumes it owns the link) collapses.

Part 2 — batched serving: the frames those clients offload are coalesced into
ONE jitted forward per model per tick (`EdgeBatchServer`), instead of one
forward per frame.  The demo verifies batched == per-frame numerics and
prints the batch statistics.

    PYTHONPATH=src python examples/edge_server_demo.py
"""
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import numpy as np  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core import PolicySpec  # noqa: E402
from repro.serving import (  # noqa: E402
    BatchedEndpoint,
    EdgeBatchServer,
    OffloadRequest,
    make_synthetic_video,
)
from repro.session import FleetSpec, ScenarioSpec, Session, TraceSpec  # noqa: E402

N_CLIENTS = 4
N_FRAMES = 60

# --- Part 1: contention on the shared uplink --------------------------------
print(f"== {N_CLIENTS} clients, 12 Mbps shared uplink, 4 server slots ==")
for allocation in ("weighted_fair", "priority", "fifo"):
    spec = ScenarioSpec(
        policy=PolicySpec("max_accuracy"),
        n_frames=N_FRAMES,
        trace=TraceSpec(mbps=12.0),
        fleet=FleetSpec(
            n_clients=N_CLIENTS, allocation=allocation, capacity=4, priorities=(0, 0, 1, 1)
        ),
        label=f"edge_server_demo/{allocation}",
    )
    rep = Session(spec).run_multi()
    per = " ".join(
        f"c{i}:acc={s.accuracy_sum / s.frames_total:.2f},edge={s.frames_offloaded}"
        for i, s in enumerate(rep.streams)
    )
    print(f"{allocation:14s} agg_acc={rep.aggregate_accuracy:.3f} "
          f"max_miss={rep.max_miss_rate:.2f}  {per}")

# --- Part 2: batched serving of the offloaded frames ------------------------
print("\n== batched edge endpoint: one forward per model per tick ==")
res, n_classes = 32, 10
rng = np.random.default_rng(0)
W = jnp.asarray(rng.standard_normal((res * res * 3, n_classes)).astype(np.float32) * 0.05)


def toy_edge_forward(x):
    """Stand-in edge model (linear probe); swap in launch/serve.py's trained
    classifiers for the full pipeline — the batching path is identical."""
    return jnp.tanh(x).reshape(x.shape[0], -1) @ W


endpoint = BatchedEndpoint("edge-toy", toy_edge_forward, max_batch=16)
frames, _labels = make_synthetic_video(N_CLIENTS * N_FRAMES, n_classes=n_classes, res=res)
endpoint.warmup(frames[0])
server = EdgeBatchServer({0: endpoint})

# Each tick: every client offloads its current frame; one flush serves all.
t0 = time.perf_counter()
batched_out = {}
for f in range(N_FRAMES):
    for c in range(N_CLIENTS):
        server.submit(OffloadRequest(c, f, 0, frames[c * N_FRAMES + f]))
    batched_out.update(server.flush())
t_batched = time.perf_counter() - t0
mean_batch, pad_fraction = endpoint.stats.mean_batch, endpoint.stats.pad_fraction

t0 = time.perf_counter()
single_out = {}
for f in range(N_FRAMES):
    for c in range(N_CLIENTS):
        single_out[(c, f)] = endpoint(frames[c * N_FRAMES + f][None])[0]
t_single = time.perf_counter() - t0

max_err = max(
    float(np.max(np.abs(batched_out[k] - single_out[k]))) for k in batched_out
)
print(f"served {len(batched_out)} frames; batched==per-frame max|err|={max_err:.2e}")
print(f"mean batch {mean_batch:.1f}, pad fraction {pad_fraction:.2f}")
print(f"wall: batched {t_batched * 1e3:.0f} ms vs per-frame {t_single * 1e3:.0f} ms "
      f"({t_single / max(t_batched, 1e-9):.1f}x)")
