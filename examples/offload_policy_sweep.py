"""Reproduce Fig. 5/9 interactively: sweep bandwidth and compare policies on
accuracy and utility — the paper's core result in one script.

    PYTHONPATH=src python examples/offload_policy_sweep.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.core import PAPER_MODELS, PAPER_STREAM, Trace, make_policy, simulate  # noqa: E402

BANDS = (0.5, 1.0, 1.5, 2.0, 2.5, 3.0, 3.5)

print("Fig.5 (accuracy):  B_Mbps  max_accuracy  local  offload  deepdecision")
for mbps in BANDS:
    row = [f"{mbps:18.1f}"]
    for pol in ("max_accuracy", "local", "offload", "deepdecision"):
        st = simulate(make_policy(pol), list(PAPER_MODELS), PAPER_STREAM,
                      Trace.constant(mbps), 120)
        row.append(f"{st.mean_accuracy:12.3f}")
    print(" ".join(row))

print("\nFig.9 (utility, alpha=200):")
for mbps in BANDS:
    row = [f"{mbps:18.1f}"]
    for pol in ("max_utility", "local", "offload"):
        st = simulate(make_policy(pol, alpha=200.0), list(PAPER_MODELS), PAPER_STREAM,
                      Trace.constant(mbps), 120)
        row.append(f"{st.utility(200.0):12.1f}")
    print(" ".join(row))
