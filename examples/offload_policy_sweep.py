"""Reproduce Fig. 5/9 interactively: sweep bandwidth and compare policies on
accuracy and utility — the paper's core result in one script.  Each policy's
whole bandwidth sweep is ONE declarative ``SweepGrid`` through
``Session.run_sweep`` (batched on device for ``jax_*`` policies, reference
loop otherwise — see docs/simulation.md), so adding a policy to the table is
just another registry name.

    PYTHONPATH=src python examples/offload_policy_sweep.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.core import PolicySpec  # noqa: E402
from repro.session import ScenarioSpec, Session, SweepGrid  # noqa: E402

BANDS = (0.5, 1.0, 1.5, 2.0, 2.5, 3.0, 3.5)


def sweep(policy: str, params: dict | None = None):
    """One bandwidth sweep -> {mbps: StreamStats}."""
    spec = ScenarioSpec(policy=PolicySpec(policy, params or {}), n_frames=120)
    report = Session(spec).run_sweep(SweepGrid(bandwidth_mbps=BANDS))
    return {pt.overrides["bandwidth_mbps"]: pt.stats for pt in report}


print("Fig.5 (accuracy):  B_Mbps  max_accuracy  local  offload  deepdecision")
acc = {pol: sweep(pol) for pol in ("max_accuracy", "local", "offload", "deepdecision")}
for mbps in BANDS:
    row = [f"{mbps:18.1f}"] + [f"{acc[pol][mbps].mean_accuracy:12.3f}" for pol in acc]
    print(" ".join(row))

print("\nFig.9 (utility, alpha=200):")
util = {pol: sweep(pol, {"alpha": 200.0}) for pol in ("max_utility", "local", "offload")}
for mbps in BANDS:
    row = [f"{mbps:18.1f}"] + [f"{util[pol][mbps].utility(200.0):12.1f}" for pol in util]
    print(" ".join(row))
