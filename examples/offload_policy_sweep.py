"""Reproduce Fig. 5/9 interactively: sweep bandwidth and compare policies on
accuracy and utility — the paper's core result in one script.  Each cell is a
declarative ScenarioSpec run through the Session front door, so adding a
policy to the sweep is just another registry name.

    PYTHONPATH=src python examples/offload_policy_sweep.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.core import PolicySpec  # noqa: E402
from repro.session import ScenarioSpec, Session, TraceSpec  # noqa: E402

BANDS = (0.5, 1.0, 1.5, 2.0, 2.5, 3.0, 3.5)


def run(policy: str, mbps: float, params: dict | None = None):
    spec = ScenarioSpec(
        policy=PolicySpec(policy, params or {}), n_frames=120, trace=TraceSpec(mbps=mbps)
    )
    return Session(spec).run_sim().stats


print("Fig.5 (accuracy):  B_Mbps  max_accuracy  local  offload  deepdecision")
for mbps in BANDS:
    row = [f"{mbps:18.1f}"]
    for pol in ("max_accuracy", "local", "offload", "deepdecision"):
        row.append(f"{run(pol, mbps).mean_accuracy:12.3f}")
    print(" ".join(row))

print("\nFig.9 (utility, alpha=200):")
for mbps in BANDS:
    row = [f"{mbps:18.1f}"]
    for pol in ("max_utility", "local", "offload"):
        row.append(f"{run(pol, mbps, {'alpha': 200.0}).utility(200.0):12.1f}")
    print(" ".join(row))
