"""End-to-end serving driver (the paper's kind): real JAX classifiers behind
the FastVA controller — an int8 "NPU" variant and a full-precision "edge"
variant of ResNet + SqueezeNet, profiled live, scheduling a synthetic video
under a 200 ms/frame deadline.

    PYTHONPATH=src python examples/serve_video.py --frames 200 --bandwidth 2.0
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.launch import serve  # noqa: E402

if __name__ == "__main__":
    serve.main()
