"""Train a reduced-config architecture for a few hundred steps on CPU with
checkpointing — the same driver a pod run uses.

    PYTHONPATH=src python examples/train_quickstart.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.launch import train  # noqa: E402

if __name__ == "__main__":
    train.main(
        [
            "--arch", "qwen3-0.6b", "--smoke",
            "--steps", "200", "--batch", "8", "--seq", "64",
            "--lr", "1e-3", "--ckpt-dir", "/tmp/repro_quickstart_ckpt",
            "--ckpt-every", "50", "--log-every", "20",
        ]
    )
